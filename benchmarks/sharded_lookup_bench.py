"""Sharded semantic-cache lookup throughput vs shard count.

Measures ``ShardedKernelBackend.top1_batch`` (the ``lookup_batch`` hot
path) over store sizes 4096 → 262144 at shard counts {1, 2, 4, 8}, plus
the ``NumpyBackend`` host scan as the single-host reference.  Results land
in ``bench_results/sharded_lookup_bench.json``.

``main()`` forces 8 host placeholder devices (same trick as
``repro.launch.dryrun``) so the ``shard_map`` path runs the real mesh
fan-out even on a 1-CPU box.  The flag only takes effect when jax has not
initialized its backend yet — standalone runs and a leading position in a
``benchmarks.run`` pick both qualify; after another suite has touched jax,
shard counts above the device count transparently use the single-device
fallback loop (identical math, no cross-device scaling; the per-row
``mesh`` field records which path ran).  The mutation is deliberately NOT
at import time: merely importing this module must not change the device
topology other suites run under.

    PYTHONPATH=src python -m benchmarks.sharded_lookup_bench
    PYTHONPATH=src python -m benchmarks.sharded_lookup_bench --pallas
    SHARDED_BENCH_DEVICES=4 PYTHONPATH=src python -m benchmarks.sharded_lookup_bench
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def _force_host_devices():
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        " --xla_force_host_platform_device_count=" +
        os.environ.get("SHARDED_BENCH_DEVICES", "8")).strip()

SHARD_COUNTS = [1, 2, 4, 8]
STORE_SIZES = [4096, 16384, 65536, 262144]
N_QUERIES = 256
DIM = 64


def _unit(rng, n):
    x = rng.standard_normal((n, DIM)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _fill_store(n: int, n_shards: int):
    from repro.cache import ShardedStore
    store = ShardedStore(n, DIM, n_shards=n_shards)
    rng = np.random.default_rng(7)
    embs = _unit(rng, n)
    for i in range(n):
        store.insert(i, embs[i])
    return store


def bench(n: int, n_shards: int, use_pallas: bool, repeats: int = 3) -> dict:
    from repro.cache import ShardedKernelBackend
    from .common import emit
    backend = ShardedKernelBackend(n_shards=n_shards, use_pallas=use_pallas)
    store = _fill_store(n, n_shards)
    rng = np.random.default_rng(13)
    queries = _unit(rng, N_QUERIES)
    backend.top1_batch(store, queries[:8])            # warm up (jit, upload)
    backend.top1_batch(store, queries)

    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        backend.top1_batch(store, queries)
        best = min(best, time.perf_counter() - t0)
    row = {"store": n, "shards": n_shards, "pallas": use_pallas,
           "mesh": backend.mesh() is not None,
           "qps": N_QUERIES / best,
           "us_per_query": 1e6 * best / N_QUERIES}
    emit(f"sharded_lookup/store={n}/shards={n_shards}",
         row["us_per_query"],
         f"qps={row['qps']:.0f},mesh={int(row['mesh'])}")
    return row


def bench_numpy(n: int, repeats: int = 3) -> dict:
    from repro.cache import NumpyBackend
    from .common import emit
    store = _fill_store(n, 1)
    rng = np.random.default_rng(13)
    queries = _unit(rng, N_QUERIES)
    nb = NumpyBackend()
    nb.top1_batch(store, queries)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        nb.top1_batch(store, queries)
        best = min(best, time.perf_counter() - t0)
    row = {"store": n, "shards": 0, "pallas": False, "mesh": False,
           "qps": N_QUERIES / best, "us_per_query": 1e6 * best / N_QUERIES}
    emit(f"sharded_lookup/store={n}/numpy", row["us_per_query"],
         f"qps={row['qps']:.0f}")
    return row


def main(argv=None):
    _force_host_devices()
    from .common import save_json
    ap = argparse.ArgumentParser()
    ap.add_argument("--pallas", action="store_true",
                    help="score shards with the Pallas kernel (interpret "
                         "mode on CPU — slow; default is the jnp oracle)")
    ap.add_argument("--sizes", type=int, nargs="*", default=STORE_SIZES)
    ap.add_argument("--shards", type=int, nargs="*", default=SHARD_COUNTS)
    args = ap.parse_args(argv)
    rows = [bench_numpy(n) for n in args.sizes]
    rows += [bench(n, s, args.pallas)
             for n in args.sizes for s in args.shards]
    save_json("sharded_lookup_bench.json", rows)
    return rows


if __name__ == "__main__":
    main()
