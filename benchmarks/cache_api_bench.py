"""Micro-benchmark: single vs. batched lookup throughput through the
unified ``SemanticCache`` facade at store sizes {256, 4096, 65536}.

The batched path amortizes one backend dispatch (one masked matmul on the
numpy backend; one ``sim_top1`` kernel launch on the kernel backend) over
the whole query block — the hot-path win the facade exists for.

    PYTHONPATH=src python -m benchmarks.cache_api_bench
    PYTHONPATH=src python -m benchmarks.cache_api_bench --backend kernel
    PYTHONPATH=src python -m benchmarks.cache_api_bench --backend kernel --no-pallas
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.cache import CacheConfig, SemanticCache

from .common import emit, save_json

STORE_SIZES = [256, 4096, 65536]
N_QUERIES = 1024
DIM = 64


def _unit(rng, n):
    x = rng.standard_normal((n, DIM)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def build_cache(n: int, backend: str, use_pallas: bool) -> SemanticCache:
    cache = SemanticCache(CacheConfig(capacity=n, dim=DIM, backend=backend,
                                      policy="LRU", use_pallas=use_pallas))
    rng = np.random.default_rng(7)
    embs = _unit(rng, n)
    for i in range(n):
        cache.admit(i, embs[i])
    return cache


def bench(n: int, backend: str, use_pallas: bool, repeats: int = 3) -> dict:
    cache = build_cache(n, backend, use_pallas)
    rng = np.random.default_rng(13)
    queries = _unit(rng, N_QUERIES)
    cache.peek_batch(queries[:8])                     # warm up (jit etc.)
    cache.lookup(queries[0])

    def timed(fn):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_single = timed(lambda: [cache.lookup(q) for q in queries])
    t_batch = timed(lambda: cache.lookup_batch(queries))
    row = {"store": n, "backend": backend, "pallas": use_pallas,
           "single_qps": N_QUERIES / t_single,
           "batched_qps": N_QUERIES / t_batch,
           "speedup": t_single / t_batch}
    emit(f"cache_lookup/store={n}/single", 1e6 * t_single / N_QUERIES,
         f"qps={row['single_qps']:.0f}")
    emit(f"cache_lookup/store={n}/batched", 1e6 * t_batch / N_QUERIES,
         f"qps={row['batched_qps']:.0f},speedup={row['speedup']:.1f}x")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "kernel"])
    ap.add_argument("--no-pallas", action="store_true",
                    help="kernel backend via the jnp oracle (fast on CPU)")
    ap.add_argument("--sizes", type=int, nargs="*", default=STORE_SIZES)
    args = ap.parse_args(argv)
    rows = [bench(n, args.backend, not args.no_pallas) for n in args.sizes]
    save_json(f"cache_api_bench_{args.backend}.json", rows)
    return rows


if __name__ == "__main__":
    main()
