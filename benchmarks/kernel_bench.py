"""Kernel micro-bench: Pallas (interpret mode on CPU — correctness-path
timing only; TPU wall-times come from the roofline terms) vs the jnp
reference, plus the oracle itself under jit."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit, save_json


def _time(fn, *args, iters=5):
    fn(*args)                                     # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    res = {}
    # similarity top-1: serving-shaped (batch of 128 queries x 4k entries)
    q = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((4096, 64)), jnp.float32)
    res["sim_top1/pallas_interp"] = _time(
        lambda a, b: ops.sim_top1(a, b), q, c)
    res["sim_top1/xla_ref"] = _time(
        jax.jit(lambda a, b: ref.sim_top1_ref(a, b, b.shape[0])), q, c)

    b, h, hkv, s, d = 1, 4, 2, 512, 128
    qa = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    ka = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    va = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    res["flash_attn/pallas_interp"] = _time(
        lambda *x: ops.flash_attention(*x), qa, ka, va)
    res["flash_attn/xla_ref"] = _time(
        jax.jit(lambda *x: ref.attention_ref(*x)), qa, ka, va)

    qd = jnp.asarray(rng.standard_normal((4, h, d)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((4, 2048, hkv, d)), jnp.float32)
    vd = jnp.asarray(rng.standard_normal((4, 2048, hkv, d)), jnp.float32)
    pos = jnp.asarray([100, 500, 1500, 2000], jnp.int32)
    res["decode_attn/pallas_interp"] = _time(
        lambda *x: ops.decode_attention(*x), qd, kd, vd, pos)
    res["decode_attn/xla_ref"] = _time(
        jax.jit(lambda *x: ref.decode_attention_ref(*x)), qd, kd, vd, pos)

    tsi = jnp.asarray(rng.random(4096), jnp.float32)
    tid = jnp.asarray(rng.integers(0, 128, 4096), jnp.int32)
    tp = jnp.asarray(rng.random(128), jnp.float32)
    tl = jnp.asarray(rng.integers(0, 1000, 128), jnp.int32)
    res["rac_value/pallas_interp"] = _time(
        lambda *x: ops.rac_value(*x, 0.001, 1500), tsi, tid, tp, tl)
    res["rac_value/xla_ref"] = _time(
        jax.jit(lambda *x: ref.rac_value_ref(*x, 0.001, 1500)),
        tsi, tid, tp, tl)
    return res


def main():
    res = run()
    for name, us in res.items():
        emit(f"kernel/{name}", us, "interpret-mode CPU timing")
    save_json("kernels.json", res)
    return res


if __name__ == "__main__":
    main()
