"""Multi-policy arena vs sequential per-policy replay (the fig-suite sweep).

Replays one 50k-request synthetic trace through the paper's 11-baseline
policy set (§4.2 — the exact configuration the fig2/fig3 sweeps run), at
the paper's standard capacity points (2.5% / 10% / 20% of the unique
footprint, the fig3 axis), two ways:

  - **sequential**: the pre-arena protocol — one full ``run_policy`` pass
    per policy over the retained legacy host-loop baselines
    (``repro.core.legacy_policies``), the historical figure-suite cost;
  - **arena**: ONE pass through ``repro.core.arena.run_arena`` — the
    array-state policies share the trace walk, the chunk embedding stack,
    and (in semantic mode) a single policy-stacked Top-1 snapshot launch
    per chunk.

Hit/miss/eviction counts are asserted bit-identical between the two paths
for every policy before any number is reported, so the speedup is never a
decision drift in disguise.  ``--smoke`` runs the content-mode 50k sweep
at the 10% and 20% capacity points and asserts the AGGREGATE arena
throughput (total sequential wall / total arena wall) is >= 3x (the PR
acceptance bar; the arena side is measured best-of-2 so a transient
scheduler stall cannot fail the cheap measurement).  The full mode adds
the 2.5% capacity point, the semantic-mode sweep, and a chunk sweep,
writing ``bench_results/policy_arena_bench.json``.

    PYTHONPATH=src python -m benchmarks.policy_arena_bench [--smoke]

Env knobs: ARENA_TRACE_LEN (default 50000).
"""
from __future__ import annotations

import inspect
import os
import sys
import time

from repro.core import SynthConfig, run_many, synthetic_trace
from repro.core.legacy_policies import LEGACY_BASELINES
from repro.core.policies import BASELINES

from .common import PAPER_BASELINES, emit, save_json

TRACE_LEN = int(os.environ.get("ARENA_TRACE_LEN", "50000"))
CAP_FRACS = (0.025, 0.10, 0.20)     # fig3's capacity axis
SMOKE_FRACS = (0.10, 0.20)
SPEEDUP_FLOOR = 3.0                 # asserted in smoke mode (PR acceptance)


def _facs(registry, names, seed=0):
    out = {}
    for n in names:
        cls = registry[n]
        takes_seed = "seed" in inspect.signature(cls.__init__).parameters

        def f(cap, store, seed=seed, _c=cls, _s=takes_seed):
            return _c(cap, store, **({"seed": seed} if _s else {}))

        f.__name__ = n
        out[n] = f
    return out


def _counts(stats):
    return [(s.policy, s.hits, s.misses, s.evictions) for s in stats]


def sweep(hit_mode: str, cap_frac: float = 0.10, chunk: int = 512,
          names=None, arena_reps: int = 1) -> dict:
    """One sequential-vs-arena comparison; returns the result record.
    ``arena_reps`` takes best-of-N on the (cheap) arena side so a noisy
    scheduler can't fail the smoke assert on the cheap measurement."""
    names = names or PAPER_BASELINES
    tr = synthetic_trace(SynthConfig(trace_len=TRACE_LEN, seed=0))
    cap = max(8, int(cap_frac * tr.meta["unique"]))
    leg = _facs(LEGACY_BASELINES, names)
    arr = _facs(BASELINES, names)

    t_arena = float("inf")
    for _ in range(max(1, arena_reps)):
        t0 = time.perf_counter()
        arena = run_many(tr, cap, arr, arena=True, hit_mode=hit_mode,
                         chunk=chunk, use_pallas=False)
        t_arena = min(t_arena, time.perf_counter() - t0)

    t0 = time.perf_counter()
    seq = run_many(tr, cap, leg, hit_mode=hit_mode, use_pallas=False)
    t_seq = time.perf_counter() - t0

    # the speedup only counts if the decisions are the same decisions
    assert _counts(seq) == _counts(arena), (
        f"arena decisions diverged from sequential replay ({hit_mode})")

    n_req = len(tr.requests) * len(names)
    return {
        "hit_mode": hit_mode, "chunk": chunk, "policies": names,
        "trace_len": TRACE_LEN, "capacity": cap, "cap_frac": cap_frac,
        "seq_s": t_seq, "arena_s": t_arena,
        "speedup": t_seq / t_arena,
        "seq_us_per_req": 1e6 * t_seq / n_req,
        "arena_us_per_req": 1e6 * t_arena / n_req,
        "hit_ratio": {s.policy: s.hit_ratio for s in arena},
    }


def main(argv=None) -> dict:
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    results = {}

    fracs = SMOKE_FRACS if smoke else CAP_FRACS
    for frac in fracs:
        r = sweep("content", cap_frac=frac, arena_reps=2 if smoke else 1)
        results[f"content_cap{frac}"] = r
        emit(f"arena/content_cap{frac}", r["arena_us_per_req"],
             f"seq={r['seq_s']:.1f}s arena={r['arena_s']:.1f}s "
             f"speedup={r['speedup']:.2f}x (counts identical)")
    if smoke:
        seq = sum(results[f"content_cap{f}"]["seq_s"] for f in fracs)
        arena = sum(results[f"content_cap{f}"]["arena_s"] for f in fracs)
        results["aggregate_speedup"] = seq / arena
        emit("arena/aggregate", 0.0, f"speedup={seq / arena:.2f}x")
        assert seq / arena >= SPEEDUP_FLOOR, (
            f"aggregate arena speedup {seq / arena:.2f}x below the "
            f"{SPEEDUP_FLOOR}x acceptance floor on the {TRACE_LEN}-request "
            f"multi-policy sweep")
        save_json("policy_arena_bench_smoke.json", results)
        return results

    r = sweep("semantic")
    results["semantic"] = r
    emit("arena/semantic", r["arena_us_per_req"],
         f"seq={r['seq_s']:.1f}s arena={r['arena_s']:.1f}s "
         f"speedup={r['speedup']:.2f}x (counts identical)")

    for chunk in (64, 2048):
        r = sweep("semantic", chunk=chunk)
        results[f"semantic_chunk{chunk}"] = r
        emit(f"arena/semantic_chunk{chunk}", r["arena_us_per_req"],
             f"speedup={r['speedup']:.2f}x")

    save_json("policy_arena_bench.json", results)
    return results


if __name__ == "__main__":
    main()
