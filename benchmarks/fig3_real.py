"""Paper Figure 3: normalized hit ratio on timestamp-continuous OASST1-style
sub-traces under capacities 2.5% / 10% / 20% of the unique footprint.

The OASST1 corpus itself is unavailable offline; the generator reproduces
its structure (interleaved threads, chronological timestamps, cross-user
prompt repeats) — see DESIGN.md §6.
"""
from __future__ import annotations

import numpy as np

from repro.core import OASSTConfig, oasst_style_trace

from .common import (N_SEEDS, TRACE_LEN, Timer, emit, factories, gains,
                     run_setting, save_json)

N_SUBTRACES = 5   # paper uses 10; override with BENCH_SEEDS


def run(capacity_fracs=(0.025, 0.10, 0.20), n_traces=None):
    n = n_traces or max(N_SEEDS, 5)
    traces = [oasst_style_trace(OASSTConfig(trace_len=TRACE_LEN, seed=s))
              for s in range(n)]
    results = {}
    for frac in capacity_fracs:
        rows = []
        for tr in traces:
            cap = max(8, int(frac * tr.meta["unique"]))
            rows.append(run_setting(tr, cap, factories()))
        # normalized HR means
        means = {k: float(np.mean([r[k].hr_norm for r in rows]))
                 for k in rows[0]}
        raw = {k: float(np.mean([r[k].hit_ratio for r in rows]))
               for k in rows[0]}
        results[f"cap={frac}"] = {"hr_norm": means, "means": raw,
                                  **gains(raw)}
    return results


def main():
    with Timer() as t:
        res = run()
    for k, v in res.items():
        emit(f"fig3/{k}", t.us / len(res),
             f"rac_norm={v['hr_norm']['RAC']:.4f} "
             f"gain_vs_best={100*v['gain_vs_best']:+.1f}% "
             f"gain_vs_avg={100*v['gain_vs_avg']:+.1f}%")
    save_json("fig3.json", res)
    return res


if __name__ == "__main__":
    main()
