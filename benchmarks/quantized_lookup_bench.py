"""Quantized int8 lookup-scan throughput vs the exact fp32 path.

The tentpole claim: candidate generation over an int8 per-row-scaled
mirror moves ~4× fewer slab bytes than the fp32 scan while producing
**identical** hit/miss decisions (rescore + safety predicate, exact
fallback otherwise).  This benchmark drives ``KernelBackend.top1_batch``
both ways over one 50k-entry store and reports:

- the decision fingerprint (cids + sims), asserted **bit-equal**;
- the byte ledger from ``quant_stats`` — ``bytes_exact`` (what the fp32
  scan reads) vs ``bytes_scanned`` (int8 slab + scales + fp32 rescore
  rows + any fallback re-scans).  The run *asserts* a minimum traffic
  reduction (default 3.0×, env ``BENCH_QUANT_MIN_TRAFFIC``) — CI smoke
  runs this as a regression gate, same pattern as the telemetry
  overhead budget;
- measured wall-clock and the roofline view: effective GB/s = fp32-
  equivalent bytes served per second of scan, against ``HBM_BW``
  (819 GB/s, the v5e HBM roof the dry-run roofline uses).  On the CPU
  oracle path the modeled numbers are the headline; on a real
  accelerator the measured ones are;
- a tau calibration curve: per-tau exact-fallback rate, plus the false
  hits/misses an *unverified* path (trust the int8 scores, skip the
  rescore) would have produced — the verified path's count is zero by
  construction, the curve shows what the safety predicate buys.

Every row also lands as a ``lookup_scan`` JSONL record in
``bench_results/lookup_scan.jsonl``; ``benchmarks.roofline`` renders
those as its second table.

    PYTHONPATH=src python -m benchmarks.quantized_lookup_bench
    PYTHONPATH=src python -m benchmarks.quantized_lookup_bench --smoke
    PYTHONPATH=src python -m benchmarks.quantized_lookup_bench --pallas
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from .common import OUT_DIR, emit, save_json

# the same HBM roof the dry-run roofline models (v5e: 819 GB/s/chip)
HBM_BW = float(os.environ.get("BENCH_HBM_BW", 819e9))
MIN_TRAFFIC = float(os.environ.get("BENCH_QUANT_MIN_TRAFFIC", "3.0"))

N_ENTRIES = 50_000
DIM = 128
N_QUERIES = 256
TAUS = (0.70, 0.80, 0.85, 0.90, 0.95)


def _unit(rng, n, dim):
    x = rng.standard_normal((n, dim)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _fill_store(n: int, dim: int):
    from repro.core import ResidentStore
    store = ResidentStore(n, dim)
    rng = np.random.default_rng(7)
    embs = _unit(rng, n, dim)
    for i in range(n):
        store.insert(i, embs[i])
    return store, embs


def _queries(embs: np.ndarray, n_q: int):
    """Half near-duplicates of resident rows (the tau band is live),
    half fresh directions (certain misses)."""
    rng = np.random.default_rng(13)
    dim = embs.shape[1]
    base = embs[rng.integers(0, embs.shape[0], size=n_q)]
    jit = 0.08 * rng.standard_normal((n_q, dim)).astype(np.float32)
    near = base + jit
    fresh = _unit(rng, n_q, dim)
    q = np.where((np.arange(n_q) % 2 == 0)[:, None], near, fresh)
    return (q / np.linalg.norm(q, axis=1, keepdims=True)).astype(np.float32)


def _time(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_pair(n: int, dim: int, k: int, tau: float, use_pallas: bool,
               repeats: int, n_q: int = N_QUERIES) -> dict:
    """One exact-vs-quantized cell; asserts bit parity and returns the
    measured + modeled throughput row."""
    from repro.cache import KernelBackend
    store, embs = _fill_store(n, dim)
    queries = _queries(embs, n_q)

    ex = KernelBackend(use_pallas=use_pallas)
    qz = KernelBackend(use_pallas=use_pallas,
                       quantized={"k": k, "tau_hit": tau})
    c0, s0 = ex.top1_batch(store, queries)          # warm (jit + upload)
    c1, s1 = qz.top1_batch(store, queries)
    # decision fingerprint: the kernel backend contract is BIT parity
    np.testing.assert_array_equal(c0, c1)
    np.testing.assert_array_equal(s0, s1)

    t_exact = _time(lambda: ex.top1_batch(store, queries), repeats)
    qz.quant_stats.update(scans=0, queries=0, fallbacks=0, rescore_rows=0,
                          bytes_scanned=0, bytes_exact=0)
    t_quant = _time(lambda: qz.top1_batch(store, queries), repeats)
    from .pruned_lookup_bench import _dispatch_delta
    disp = _dispatch_delta(lambda: qz.top1_batch(store, queries))

    st = qz.quant_stats
    per_scan_q = st["bytes_scanned"] / st["scans"]
    per_scan_e = st["bytes_exact"] / st["scans"]
    traffic_ratio = per_scan_e / per_scan_q
    row = {
        # unified lookup_scan schema: every reduced-traffic path (quant,
        # pruned, pruned+quant) emits path/rows_per_query/bytes_scanned
        # so benchmarks.roofline renders them as rows of ONE table
        "path": "quant",
        "n": n, "dim": dim, "k": k, "tau": tau, "pallas": use_pallas,
        "queries": n_q,
        "rows_per_query": float(n),      # int8 still scans every row
        "t_exact_s": t_exact, "t_quant_s": t_quant,
        "speedup": t_exact / t_quant,
        "bytes_exact": per_scan_e, "bytes_quant": per_scan_q,
        "bytes_scanned": per_scan_q,
        "traffic_ratio": traffic_ratio,
        "fallback_rate": st["fallbacks"] / st["queries"],
        "rescore_rows": st["rescore_rows"] / st["scans"],
        # measured: bytes the path actually moved per second of scan
        "gbps_exact": per_scan_e / t_exact / 1e9,
        "gbps_quant": per_scan_q / t_quant / 1e9,
        # effective: fp32-equivalent bytes served per second — the
        # roofline headline (>= 2x exact when traffic_ratio covers it)
        "effective_gbps": per_scan_e / t_quant / 1e9,
        # modeled at the HBM roof: what a memory-bound device pays
        "t_exact_roof_s": per_scan_e / HBM_BW,
        "t_quant_roof_s": per_scan_q / HBM_BW,
        "roof_speedup": traffic_ratio,
        "hbm_bw": HBM_BW,
        # dispatch ledger for one batch pass (launches / blocking syncs /
        # timed kernel-interval seconds — roofline's kernel-roof view)
        "launches": disp["launches"],
        "host_syncs": disp["host_syncs"],
        "t_kernel_s": disp["kernel_s"],
    }
    emit(f"quantized_lookup/n={n}/k={k}/tau={tau}",
         1e6 * t_quant / n_q,
         f"traffic={traffic_ratio:.2f}x,speedup={row['speedup']:.2f}x,"
         f"fallback={100 * row['fallback_rate']:.1f}%,"
         f"eff={row['effective_gbps']:.1f}GB/s")
    return row


def _band_queries(embs: np.ndarray, n_q: int, tau: float, width: float,
                  seed: int):
    """Queries whose TRUE top-1 sim lands uniformly in ``tau ± width`` —
    the adversarial band where int8 noise can flip a naive threshold."""
    rng = np.random.default_rng(seed)
    dim = embs.shape[1]
    base = embs[rng.integers(0, embs.shape[0], size=n_q)]
    orth = rng.standard_normal((n_q, dim)).astype(np.float32)
    orth -= np.sum(orth * base, axis=1, keepdims=True) * base
    orth /= np.linalg.norm(orth, axis=1, keepdims=True)
    s = rng.uniform(tau - width, tau + width,
                    size=n_q).astype(np.float32)[:, None]
    q = s * base + np.sqrt(1.0 - s * s) * orth
    return (q / np.linalg.norm(q, axis=1, keepdims=True)).astype(np.float32)


def calibration(n: int, dim: int, k: int, use_pallas: bool,
                n_q: int = N_QUERIES) -> list[dict]:
    """Per-tau curve on in-band queries (true top-1 sims within ±0.03 of
    tau): exact-fallback rate of the verified path vs the false/missed
    hits of trusting raw int8 scores without a rescore.  The verified
    path asserts zero errors per cell; the unverified columns are what
    the safety predicate is buying."""
    from repro.cache import KernelBackend
    from repro.kernels import ops
    from repro.kernels.quant import quantize_rows_int8
    store, embs = _fill_store(n, dim)
    ex = KernelBackend(use_pallas=use_pallas)
    qm_q8, qm_sc, _ = quantize_rows_int8(store.emb)

    rows = []
    for tau in TAUS:
        queries = _band_queries(embs, n_q, tau, 0.03, seed=int(tau * 1000))
        _, exact_sims = ex.top1_batch(store, queries)
        q8, qs, _ = quantize_rows_int8(queries)
        av, _ = ops.sim_topk_q8(q8, qs, qm_q8, qm_sc, 1, n_valid=store.hwm,
                                use_pallas=use_pallas)
        approx_top1 = np.asarray(av[:, 0], dtype=np.float64)

        qz = KernelBackend(use_pallas=use_pallas,
                           quantized={"k": k, "tau_hit": tau})
        _, s1 = qz.top1_batch(store, queries)
        np.testing.assert_array_equal(exact_sims, s1)   # verified: 0 errors
        raw_hit = approx_top1 >= tau
        true_hit = exact_sims >= tau
        rows.append({
            "tau": tau, "k": k, "queries": n_q,
            "fallback_rate": qz.quant_stats["fallbacks"]
            / qz.quant_stats["queries"],
            "unverified_false_hits": int(np.sum(raw_hit & ~true_hit)),
            "unverified_missed_hits": int(np.sum(~raw_hit & true_hit)),
            "verified_errors": 0,
            "true_hits": int(np.sum(true_hit)),
        })
        r = rows[-1]
        emit(f"quantized_calibration/tau={tau}", 0.0,
             f"fallback={100 * r['fallback_rate']:.1f}%,"
             f"raw_false_hits={r['unverified_false_hits']},"
             f"raw_missed={r['unverified_missed_hits']},"
             f"true_hits={r['true_hits']}/{n_q}")
    return rows


def _append_jsonl(rows: list[dict]) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "lookup_scan.jsonl")
    with open(path, "a") as f:
        for r in rows:
            f.write(json.dumps({"kind": "lookup_scan", **r}) + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--pallas", action="store_true",
                    help="int8 scans via the Pallas kernel (interpret mode "
                         "on CPU — slow; default is the jnp oracle)")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)
    n = 8_000 if args.smoke else N_ENTRIES
    n_q = 64 if args.smoke else N_QUERIES
    repeats = args.repeats or (2 if args.smoke else 5)
    ks = (4, 8) if args.smoke else (4, 8, 16)

    rows = [bench_pair(n, DIM, k, 0.85, args.pallas, repeats, n_q=n_q)
            for k in ks]
    cal = calibration(n, DIM, 8, args.pallas, n_q=n_q)

    # regression gate on the default-config (k=8) cell: the int8 path
    # must keep its memory-traffic win.  traffic_ratio is ~4x minus the
    # rescore/fallback tax (union ≤ batch·k rows, so the floor is
    # deterministic at these shapes); a fallback regression — predicate
    # bug, margin blow-up — adds whole fp32 re-scans and drags the ratio
    # below the floor immediately.
    gate = next(r for r in rows if r["k"] == 8)
    assert gate["traffic_ratio"] >= MIN_TRAFFIC, (
        f"quantized scan traffic reduction {gate['traffic_ratio']:.2f}x "
        f"fell below the {MIN_TRAFFIC:.1f}x floor (BENCH_QUANT_MIN_TRAFFIC)")

    _append_jsonl(rows)
    save_json("quantized_lookup.json",
              {"rows": rows, "calibration": cal, "hbm_bw": HBM_BW,
               "min_traffic": MIN_TRAFFIC, "smoke": args.smoke})
    return rows


if __name__ == "__main__":
    main()
