"""Roofline table reader: aggregates dry-run JSONL records (written by
repro.launch.dryrun --out) into the §Roofline table."""
from __future__ import annotations

import json
import os

from .common import emit, save_json

DEFAULT_PATHS = ("bench_results/dryrun.jsonl", "/tmp/dryrun_all.jsonl")


def load(path=None):
    paths = [path] if path else list(DEFAULT_PATHS)
    recs = []
    for p in paths:
        if p and os.path.exists(p):
            with open(p) as f:
                for line in f:
                    r = json.loads(line)
                    if "error" not in r:
                        recs.append(r)
            break
    # keep the latest record per cell (arch ids normalized: the CLI accepts
    # both assignment ids "gemma-7b" and module ids "gemma_7b")
    dedup = {}
    for r in recs:
        key = (r["arch"].replace("-", "_").replace(".", ""),
               r["shape"], r["mesh"])
        dedup[key] = r
    return list(dedup.values())


def table(recs):
    rows = []
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        t_bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
        rows.append(dict(
            cell=f"{r['arch']}×{r['shape']}×{r['mesh']}",
            t_compute_ms=1e3 * r["t_compute"],
            t_memory_ms=1e3 * r["t_memory"],
            t_collective_ms=1e3 * r["t_collective"],
            bottleneck=r["bottleneck"],
            peak_gib=r["peak_bytes_per_device"] / 2**30,
            useful_flop_frac=r.get("useful_flop_frac", float("nan")),
            roofline_frac=(r["t_compute"] / t_bound) if t_bound else 0.0,
        ))
    return rows


def main():
    recs = load()
    rows = table(recs)
    if not rows:
        emit("roofline/no-data", 0.0,
             "run `python -m repro.launch.dryrun --all --out "
             "bench_results/dryrun.jsonl` first")
        return []
    for r in rows:
        emit(f"roofline/{r['cell']}", r["t_compute_ms"] * 1e3,
             f"bottleneck={r['bottleneck']} "
             f"t=[{r['t_compute_ms']:.1f},{r['t_memory_ms']:.1f},"
             f"{r['t_collective_ms']:.1f}]ms "
             f"roofline_frac={r['roofline_frac']:.3f} "
             f"useful={r['useful_flop_frac']:.2f}")
    save_json("roofline.json", rows)
    return rows


if __name__ == "__main__":
    main()
