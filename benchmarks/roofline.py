"""Roofline table reader: aggregates dry-run JSONL records (written by
repro.launch.dryrun --out) into the §Roofline table, plus the
``lookup_scan`` records the quantized- and pruned-lookup benches append
(bench_results/lookup_scan.jsonl) as ONE unified second table — every
candidate-generation path (exact baseline, int8 ``quant``, topic-
``pruned``, composed ``pruned+quant``) renders as a row with
scanned-rows/query, scan bytes vs the HBM roof, effective GB/s, and
fallback rate, so the paths are comparable cell-for-cell instead of
living in per-bench ad-hoc tables."""
from __future__ import annotations

import json
import os

from .common import emit, save_json

DEFAULT_PATHS = ("bench_results/dryrun.jsonl", "/tmp/dryrun_all.jsonl")
LOOKUP_PATHS = ("bench_results/lookup_scan.jsonl",)


def load(path=None):
    paths = [path] if path else list(DEFAULT_PATHS)
    recs = []
    for p in paths:
        if p and os.path.exists(p):
            with open(p) as f:
                for line in f:
                    r = json.loads(line)
                    if "error" not in r:
                        recs.append(r)
            break
    # keep the latest record per cell (arch ids normalized: the CLI accepts
    # both assignment ids "gemma-7b" and module ids "gemma_7b")
    dedup = {}
    for r in recs:
        key = (r["arch"].replace("-", "_").replace(".", ""),
               r["shape"], r["mesh"])
        dedup[key] = r
    return list(dedup.values())


def table(recs):
    rows = []
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        t_bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
        rows.append(dict(
            cell=f"{r['arch']}×{r['shape']}×{r['mesh']}",
            t_compute_ms=1e3 * r["t_compute"],
            t_memory_ms=1e3 * r["t_memory"],
            t_collective_ms=1e3 * r["t_collective"],
            bottleneck=r["bottleneck"],
            peak_gib=r["peak_bytes_per_device"] / 2**30,
            useful_flop_frac=r.get("useful_flop_frac", float("nan")),
            roofline_frac=(r["t_compute"] / t_bound) if t_bound else 0.0,
        ))
    return rows


def load_lookup(path=None):
    """Latest ``lookup_scan`` record per (path, n, dim, k, probes) cell.
    Pre-unification records carry no ``path`` field — they are the int8
    bench's, so they dedup under ``"quant"``."""
    paths = [path] if path else list(LOOKUP_PATHS)
    dedup = {}
    for p in paths:
        if p and os.path.exists(p):
            with open(p) as f:
                for line in f:
                    r = json.loads(line)
                    if r.get("kind") == "lookup_scan":
                        dedup[(r.get("path", "quant"), r["n"], r["dim"],
                               r.get("k"), r.get("probes"))] = r
            break
    return list(dedup.values())


def lookup_table(recs):
    """The unified second table: one row per candidate-generation path
    cell — exact / quant / pruned / pruned+quant — with scanned-rows per
    query and scan bytes against the HBM roof."""
    rows = []
    key = lambda x: (x["n"], x["dim"], x.get("path", "quant"),
                     x.get("k") or 0, x.get("probes") or 0)
    for r in sorted(recs, key=key):
        path = r.get("path", "quant")
        scanned = r.get("bytes_scanned", r.get("bytes_quant"))
        tag = f"lookup×{r['n']}×d{r['dim']}×{path}"
        if r.get("k") is not None:
            tag += f"×k{r['k']}"
        if r.get("probes") is not None:
            tag += f"×p{r['probes']}"
        row = dict(
            cell=tag,
            path=path,
            rows_per_query=r.get("rows_per_query", float(r["n"])),
            bytes_exact_mib=r["bytes_exact"] / 2**20,
            bytes_scanned_mib=scanned / 2**20,
            traffic_ratio=r["traffic_ratio"],
            effective_gbps=r["effective_gbps"],
            t_exact_roof_us=1e6 * r["t_exact_roof_s"],
            t_scan_roof_us=1e6 * (scanned / r["hbm_bw"]),
            roof_frac=(r["effective_gbps"] * 1e9
                       * (scanned / r["bytes_exact"]) / r["hbm_bw"]),
            fallback_rate=r["fallback_rate"],
        )
        # kernel-interval view: records from dispatch-instrumented benches
        # carry the seconds spent inside the timed kernel launches per
        # scan, so the roof fraction can be judged against time the
        # device actually worked instead of wall-clock that includes the
        # host driver (decision mapping, transfers, Python)
        t_k = r.get("t_kernel_s")
        if t_k:
            row["effective_gbps_kernel"] = r["bytes_exact"] / t_k / 1e9
            row["roof_frac_kernel"] = scanned / t_k / r["hbm_bw"]
        rows.append(row)
    return rows


def main():
    recs = load()
    rows = table(recs)
    if not rows:
        emit("roofline/no-data", 0.0,
             "run `python -m repro.launch.dryrun --all --out "
             "bench_results/dryrun.jsonl` first")
    for r in rows:
        emit(f"roofline/{r['cell']}", r["t_compute_ms"] * 1e3,
             f"bottleneck={r['bottleneck']} "
             f"t=[{r['t_compute_ms']:.1f},{r['t_memory_ms']:.1f},"
             f"{r['t_collective_ms']:.1f}]ms "
             f"roofline_frac={r['roofline_frac']:.3f} "
             f"useful={r['useful_flop_frac']:.2f}")
    lrows = lookup_table(load_lookup())
    for r in lrows:
        kern = (f" eff_k={r['effective_gbps_kernel']:.1f}GB/s"
                f"(roof_frac={r['roof_frac_kernel']:.3f})"
                if "effective_gbps_kernel" in r else "")
        emit(f"roofline/{r['cell']}", r["t_scan_roof_us"],
             f"rows/q={r['rows_per_query']:.0f} "
             f"traffic={r['traffic_ratio']:.2f}x "
             f"roof=[{r['t_exact_roof_us']:.1f}->"
             f"{r['t_scan_roof_us']:.1f}]us "
             f"eff={r['effective_gbps']:.1f}GB/s "
             f"fallback={100 * r['fallback_rate']:.1f}%" + kern)
    if not rows and not lrows:
        return []
    save_json("roofline.json", {"dryrun": rows, "lookup_scan": lrows})
    return rows + lrows


if __name__ == "__main__":
    main()
