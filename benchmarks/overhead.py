"""Per-request policy overhead (µs/request, host side) — the paper argues
RAC is "lightweight to maintain online"; this quantifies it against every
baseline under identical load."""
from __future__ import annotations

import time

from repro.core import SynthConfig, run_policy, synthetic_trace

from .common import Timer, emit, factories, save_json


def run():
    tr = synthetic_trace(SynthConfig(trace_len=6000, seed=0))
    cap = max(8, int(0.10 * tr.meta["unique"]))
    out = {}
    for name, f in factories().items():
        s = run_policy(tr, cap, f, name=name)
        out[name] = {"us_per_request": 1e6 * s.wall_s / len(tr.requests),
                     "hit_ratio": s.hit_ratio}
    return out


def main():
    res = run()
    for name, v in sorted(res.items(), key=lambda kv: kv[1]["us_per_request"]):
        emit(f"overhead/{name}", v["us_per_request"],
             f"hit_ratio={v['hit_ratio']:.4f}")
    save_json("overhead.json", res)
    return res


if __name__ == "__main__":
    main()
