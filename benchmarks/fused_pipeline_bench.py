"""Device-resident fused decision pipeline vs the staged driver.

The tentpole claim: serving-shaped lookups (chunk ≤ 16 queries) spend
more on dispatch than on math — the staged pruned+quant driver makes
2–7 jitted launches and 4–14 blocking device→host syncs per chunk
(routing, candidate scan, rescore, predicate inputs), while the fused
pipeline makes exactly ONE launch and ONE sync: route → CSR gather →
int8 scan → fp32 union rescore → safety predicates in a single jitted
program.  This benchmark drives both paths over the same 50k-entry
clustered store (the pruned bench's cell: 64 topics, hot-topic-skewed
near-dup + fresh-direction queries) in a chunked serving loop and
reports, per chunk size:

- the decision fingerprint (identical hit mask, bit-equal (cid, sim) on
  hits — and full bit-equality at chunk=1, where the union rescore
  covers exactly the query's own candidate set);
- measured wall-clock speedup, gated by ``BENCH_FUSED_MIN_SPEEDUP``
  (CPU default 1.0 — the jnp-oracle launches are cheap here; the
  architectural win is the dispatch profile);
- the dispatch ledger: launches / blocking syncs / kernel-interval
  seconds per chunk from ``repro.kernels.ops.dispatch_stats``.  The run
  *asserts* the fused path stays ≤ ``BENCH_FUSED_MAX_LAUNCHES`` (default
  2) launches per steady-state chunk — the structural regression gate;
- the dispatch-bound model: pass cost = launches·``BENCH_LAUNCH_US`` +
  syncs·``BENCH_SYNC_US`` + scanned-bytes/``BENCH_HBM_BW`` — what the
  same launch/sync profile costs on an accelerator where each dispatch
  is ~20 µs, each blocking sync ~50 µs, and the scan itself runs at the
  HBM roof (both paths touch the same candidate slab — the decisions
  are fingerprint-equal — so the scan term cancels and the dispatch
  profile dominates).  Gated at the chunk=8 steady serving cell by
  ``BENCH_FUSED_MIN_MODEL_SPEEDUP`` (default 5).

The chunk=1 cell also lands as a ``lookup_scan`` JSONL record with
``path="fused"`` and its kernel-interval time, so
``benchmarks.roofline`` renders the kernel-roof view next to the staged
paths' rows.

    PYTHONPATH=src python -m benchmarks.fused_pipeline_bench
    PYTHONPATH=src python -m benchmarks.fused_pipeline_bench --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from .common import OUT_DIR, emit, save_json
from .pruned_lookup_bench import (HBM_BW, N_TOPICS, TAU, _dispatch_delta,
                                  _fill_clustered, _fingerprint, _queries)

MIN_SPEEDUP = float(os.environ.get("BENCH_FUSED_MIN_SPEEDUP", "1.0"))
MIN_MODEL_SPEEDUP = float(
    os.environ.get("BENCH_FUSED_MIN_MODEL_SPEEDUP", "5.0"))
MAX_LAUNCHES = float(os.environ.get("BENCH_FUSED_MAX_LAUNCHES", "2.0"))
# accelerator dispatch model: per-launch driver overhead and per-sync
# host round-trip (order-of-magnitude PCIe/ICI numbers, overridable)
LAUNCH_US = float(os.environ.get("BENCH_LAUNCH_US", "20.0"))
SYNC_US = float(os.environ.get("BENCH_SYNC_US", "50.0"))

N_ENTRIES = 50_000
DIM = 128
N_QUERIES = 64
PROBES = 2
K = 8
CHUNKS = (1, 8)


def _backend(use_pallas: bool, fused: bool, store, table):
    from repro.cache import KernelBackend
    bk = KernelBackend(
        use_pallas=use_pallas,
        pruned={"probes": PROBES, "tau_hit": TAU, "fused": fused},
        quantized={"k": K, "tau_hit": TAU, "fused": fused})
    bk.route_table = table          # what the facade wires from the policy
    bk.route_store = store
    return bk


def _serve(bk, store, queries, chunk: int):
    """The chunked serving loop both paths are measured on."""
    cids = np.empty(queries.shape[0], dtype=np.int64)
    sims = np.empty(queries.shape[0], dtype=np.float64)
    for i in range(0, queries.shape[0], chunk):
        c, s = bk.top1_batch(store, queries[i:i + chunk])
        cids[i:i + chunk] = c
        sims[i:i + chunk] = s
    return cids, sims


def _time(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_chunk(store, table, queries, chunk: int, use_pallas: bool,
                repeats: int) -> dict:
    """One staged-vs-fused serving cell at a fixed chunk width."""
    from repro.cache.pruned import new_prune_stats
    st_bk = _backend(use_pallas, False, store, table)
    fu_bk = _backend(use_pallas, True, store, table)
    c0, s0 = _serve(st_bk, store, queries, chunk)       # warm (jit+upload)
    c1, s1 = _serve(fu_bk, store, queries, chunk)

    # decision fingerprint: hit mask identical, hits bit-equal.  At
    # chunk=1 the streams are bit-equal outright — the fused union
    # rescore covers exactly the query's own candidates, so even the
    # certified-miss best-so-far matches the staged driver's.
    _fingerprint(TAU, c0, s0, c1, s1)
    if chunk == 1:
        np.testing.assert_array_equal(c0, c1)
        np.testing.assert_array_equal(s0, s1)

    n_chunks = (queries.shape[0] + chunk - 1) // chunk
    t_staged = _time(lambda: _serve(st_bk, store, queries, chunk), repeats)
    t_fused = _time(lambda: _serve(fu_bk, store, queries, chunk), repeats)
    d_st = _dispatch_delta(lambda: _serve(st_bk, store, queries, chunk))
    fu_bk.prune_stats.update(new_prune_stats())
    d_fu = _dispatch_delta(lambda: _serve(fu_bk, store, queries, chunk))
    ps = fu_bk.prune_stats

    # dispatch-bound accelerator model for the whole serving pass: the
    # scan term uses the HBM-roof time for the bytes the pass actually
    # scanned (identical candidate slab on both paths — the decisions
    # are fingerprint-equal), NOT the measured CPU kernel interval,
    # which says nothing about a memory-bound device
    t_roof_pass = ps["bytes_scanned"] / HBM_BW

    def model_s(d):
        return (d["launches"] * LAUNCH_US * 1e-6
                + d["host_syncs"] * SYNC_US * 1e-6 + t_roof_pass)

    per_scan_e = ps["bytes_exact"] / max(1, ps["scans"])
    per_scan_f = ps["bytes_scanned"] / max(1, ps["scans"])
    row = {
        "path": "fused", "n": store.hwm, "dim": queries.shape[1],
        "probes": PROBES, "k": K, "tau": TAU, "pallas": use_pallas,
        "queries": queries.shape[0], "chunk": chunk,
        "t_staged_s": t_staged, "t_fused_s": t_fused,
        "speedup": t_staged / t_fused,
        "launches_staged": d_st["launches"] / n_chunks,
        "launches_fused": d_fu["launches"] / n_chunks,
        "syncs_staged": d_st["host_syncs"] / n_chunks,
        "syncs_fused": d_fu["host_syncs"] / n_chunks,
        "t_kernel_staged_s": d_st["kernel_s"],
        "t_kernel_fused_s": d_fu["kernel_s"],
        "model_staged_s": model_s(d_st),
        "model_fused_s": model_s(d_fu),
        "model_speedup": model_s(d_st) / model_s(d_fu),
        "launch_us": LAUNCH_US, "sync_us": SYNC_US,
        # unified lookup_scan fields (per-chunk scan normalization)
        "rows_per_query": ps["scanned_rows"] / max(1, ps["queries"]),
        "rows_ratio": ps["rows_exact"] / max(1, ps["scanned_rows"]),
        "bytes_exact": per_scan_e, "bytes_scanned": per_scan_f,
        "traffic_ratio": per_scan_e / max(1.0, per_scan_f),
        "fallback_rate": ps["fallbacks"] / max(1, ps["queries"]),
        "effective_gbps": per_scan_e / (t_fused / n_chunks) / 1e9,
        "t_exact_roof_s": per_scan_e / HBM_BW,
        "t_kernel_s": d_fu["kernel_s"] / n_chunks,
        "hbm_bw": HBM_BW,
    }
    emit(f"fused_pipeline/n={store.hwm}/chunk={chunk}",
         1e6 * t_fused / queries.shape[0],
         f"speedup={row['speedup']:.2f}x,"
         f"model={row['model_speedup']:.2f}x,"
         f"launches/chunk={row['launches_fused']:.1f}"
         f"(staged {row['launches_staged']:.1f}),"
         f"syncs/chunk={row['syncs_fused']:.1f}"
         f"(staged {row['syncs_staged']:.1f})")
    return row


def _append_jsonl(rows: list[dict]) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "lookup_scan.jsonl")
    with open(path, "a") as f:
        for r in rows:
            f.write(json.dumps({"kind": "lookup_scan", **r}) + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--pallas", action="store_true",
                    help="device scans via the Pallas kernels (interpret "
                         "mode on CPU — slow; default is the jnp oracle)")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)
    n = 8_000 if args.smoke else N_ENTRIES
    n_q = 32 if args.smoke else N_QUERIES
    repeats = args.repeats or (2 if args.smoke else 3)

    store, table, embs, assign = _fill_clustered(n, DIM, N_TOPICS)
    queries = _queries(embs, assign, n_q, N_TOPICS)
    rows = [bench_chunk(store, table, queries, c, args.pallas, repeats)
            for c in CHUNKS]

    # structural regression gate: the fused path must stay one-launch/
    # one-sync shaped per steady-state chunk (>2 means a stage fell out
    # of the fused program or a mirror re-upload leaked into the loop)
    for r in rows:
        assert r["launches_fused"] <= MAX_LAUNCHES, (
            f"fused path made {r['launches_fused']:.1f} launches/chunk at "
            f"chunk={r['chunk']} (max {MAX_LAUNCHES:.0f}, "
            f"BENCH_FUSED_MAX_LAUNCHES)")

    gate = next(r for r in rows if r["chunk"] == 1)
    assert gate["speedup"] >= MIN_SPEEDUP, (
        f"fused serving speedup {gate['speedup']:.2f}x fell below the "
        f"{MIN_SPEEDUP:.2f}x floor (BENCH_FUSED_MIN_SPEEDUP)")
    mgate = rows[-1]        # widest serving chunk: dispatch-dominated
    assert mgate["model_speedup"] >= MIN_MODEL_SPEEDUP, (
        f"dispatch-bound model speedup {mgate['model_speedup']:.2f}x at "
        f"chunk={mgate['chunk']} fell below the {MIN_MODEL_SPEEDUP:.2f}x "
        f"floor (BENCH_FUSED_MIN_MODEL_SPEEDUP)")

    _append_jsonl([gate])
    save_json("fused_pipeline.json",
              {"rows": rows, "hbm_bw": HBM_BW,
               "min_speedup": MIN_SPEEDUP,
               "min_model_speedup": MIN_MODEL_SPEEDUP,
               "launch_us": LAUNCH_US, "sync_us": SYNC_US,
               "smoke": args.smoke})
    return rows


if __name__ == "__main__":
    main()
