"""Paper Figure 1 / Example 1, reproduced literally.

Sequence {a0..a5} → {b0..b5} → {a0, a1*..a5*} → {b0, b1*..b5*} with cache
capacity 6: two topics alternate; context anchors (a0, b2-analog) recur
while follow-up queries are fresh.  The paper's demonstration:

  (I)   traditional policies (LRU): every batch flushes the cache before
        any reuse → zero hits;
  (II)  online-learning (LeCaR as the available stand-in): cold start sees
        no reuse either;
  (III) offline optimal (Belady) keeps the anchors → hits on both re-asks;
        RAC approximates it online via TP·TSI.
"""
from __future__ import annotations

import numpy as np

from repro.core import EmbeddingSpace, Request, Trace
from repro.core.policies import BeladyPolicy, LeCaRPolicy, LRUPolicy
from repro.core.rac import RACPolicy
from repro.core.simulator import run_policy

from .common import Timer, emit, save_json


def example1_trace() -> Trace:
    space = EmbeddingSpace(dim=32, seed=42)

    def session(topic, anchor, leaves, occ):
        out = [(anchor, space.paraphrase(
            space.content_embedding(topic, anchor), topic, anchor, occ),
            anchor if occ else -1)]
        for leaf in leaves:
            out.append((leaf, space.content_embedding(
                topic, leaf, parent_content=anchor), anchor))
        return out

    stream = []
    stream += session(0, 0, [1, 2, 3, 4, 5], 0)        # {a0..a5}
    stream += session(1, 10, [11, 12, 13, 14, 15], 0)  # {b0..b5}
    stream += session(0, 0, [21, 22, 23, 24, 25], 1)   # {a0, a1*..a5*}
    stream += session(1, 10, [31, 32, 33, 34, 35], 1)  # {b0, b1*..b5*}
    reqs = [Request(t=t, cid=cid, emb=emb.astype(np.float32),
                    parent_cid=par)
            for t, (cid, emb, par) in enumerate(stream)]
    return Trace(requests=reqs).with_next_use()


def run():
    tr = example1_trace()
    cap = 6
    out = {}
    for name, fac in {
        "LRU (paper I)": lambda c, s: LRUPolicy(c, s),
        "LeCaR cold-start (paper II)": lambda c, s: LeCaRPolicy(c, s),
        "RAC (paper III approx)": lambda c, s: RACPolicy(
            c, s, tau_route=0.5, tau_edge=0.5, alpha=0.01, lam=2.0),
        "Belady offline-OPT (paper III)": lambda c, s: BeladyPolicy(c, s),
    }.items():
        out[name] = run_policy(tr, cap, fac, name=name).hits
    return out


def main():
    with Timer() as t:
        res = run()
    for name, hits in res.items():
        emit(f"fig1/{name}", t.us / len(res), f"hits={hits}/24 requests")
    save_json("fig1.json", res)
    return res


if __name__ == "__main__":
    main()
