"""Telemetry hot-path overhead: no tracker vs NoopTracker vs InMemoryTracker.

The telemetry contract is that observation is (a) decision-free and (b)
cheap enough to leave on: with ``tracker=None`` the facade adds zero work,
and with a :class:`~repro.telemetry.NoopTracker` the only cost is a couple
of no-op method calls per operation.  This benchmark replays one fixed
synthetic workload (semantic lookups + admissions at capacity, so every
admission runs a victim scan) under each sink and reports the wall-clock
ratio against the tracker-less run.

Timing is min-of-repeats with the variants interleaved round-robin, so a
background hiccup hits all variants alike instead of biasing one.  The
run *asserts* the NoopTracker overhead bound (default 5%, env
``BENCH_TELEMETRY_MAX_OVERHEAD``) — CI smoke runs this as a regression
gate on the hot path.  Decision parity across sinks is asserted too.

    PYTHONPATH=src python -m benchmarks.telemetry_overhead_bench
    PYTHONPATH=src python -m benchmarks.telemetry_overhead_bench --smoke
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.cache import CacheConfig, SemanticCache
from repro.core import SynthConfig, synthetic_trace
from repro.telemetry import InMemoryTracker, NoopTracker

from .common import emit, save_json

MAX_OVERHEAD = float(os.environ.get("BENCH_TELEMETRY_MAX_OVERHEAD", "0.05"))


def _replay(tracker, trace, capacity: int, dim: int):
    """One full pass; returns (wall_s, decision fingerprint)."""
    cache = SemanticCache(CacheConfig(
        capacity=capacity, dim=dim, tau_hit=0.85, hit_mode="semantic",
        backend="numpy", tracker=tracker))
    decisions = []
    t0 = time.perf_counter()
    for r in trace.requests:
        res = cache.lookup(r.emb, cid=r.cid)
        if not res.hit:
            cache.admit(r.cid, r.emb, payload=(r.cid,))
        decisions.append(res.hit)
    wall = time.perf_counter() - t0
    fp = (tuple(decisions), cache.metrics.hits, cache.metrics.evictions)
    cache.close()
    return wall, fp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)
    n = 1200 if args.smoke else 6000
    capacity = 128 if args.smoke else 512
    repeats = args.repeats or (5 if args.smoke else 7)
    dim = 32
    trace = synthetic_trace(SynthConfig(trace_len=n, n_topics=16, seed=11,
                                        dim=dim))

    variants = {
        "none": lambda: None,
        "noop": NoopTracker,
        "memory": InMemoryTracker,
    }
    best = {k: float("inf") for k in variants}
    fps = {}
    for make in variants.values():               # warm imports / allocators
        _replay(make(), trace, capacity, dim)
    for _ in range(repeats):
        for name, make in variants.items():      # interleaved: shared drift
            wall, fp = _replay(make(), trace, capacity, dim)
            best[name] = min(best[name], wall)
            fps[name] = fp
    assert fps["none"] == fps["noop"] == fps["memory"], \
        "telemetry changed cache decisions"

    base = best["none"]
    rows = []
    for name in variants:
        ratio = best[name] / base - 1.0
        rows.append({"tracker": name, "wall_s": best[name],
                     "us_per_lookup": 1e6 * best[name] / n,
                     "overhead_vs_none": ratio})
        emit(f"telemetry_overhead/{name}", 1e6 * best[name] / n,
             f"overhead={100 * ratio:+.2f}%")
    noop_overhead = best["noop"] / base - 1.0
    assert noop_overhead <= MAX_OVERHEAD, (
        f"NoopTracker hot-path overhead {100 * noop_overhead:.2f}% exceeds "
        f"the {100 * MAX_OVERHEAD:.0f}% budget")
    save_json("telemetry_overhead_bench.json",
              {"rows": rows, "max_overhead": MAX_OVERHEAD,
               "noop_overhead": noop_overhead,
               "requests": n, "capacity": capacity, "repeats": repeats})
    return rows


if __name__ == "__main__":
    main()
