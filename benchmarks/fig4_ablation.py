"""Paper Figure 4: ablation — RAC vs RAC w/o TP vs RAC w/o TSI across cache
capacities 2.5%..20% (step 2.5%), plus the marginal gains ΔTP / ΔTSI."""
from __future__ import annotations

import numpy as np

from repro.core import SynthConfig, synthetic_trace
from repro.core.rac import make_rac

from .common import N_SEEDS, TRACE_LEN, Timer, emit, save_json
from .common import run_setting


def run(seeds=None):
    facs = {
        "RAC": make_rac(),
        "RAC w/o TP": make_rac(use_tp=False),
        "RAC w/o TSI": make_rac(use_tsi=False),
    }
    results = {}
    for frac in np.arange(0.025, 0.2001, 0.025):
        rows = []
        for seed in range(seeds or N_SEEDS):
            tr = synthetic_trace(SynthConfig(trace_len=TRACE_LEN, seed=seed))
            cap = max(4, int(frac * tr.meta["unique"]))
            rows.append(run_setting(tr, cap, facs))
        m = {k: float(np.mean([r[k].hit_ratio for r in rows])) for k in facs}
        results[f"cap={frac:.3f}"] = {
            **m,
            "delta_tp": m["RAC"] - m["RAC w/o TP"],
            "delta_tsi": m["RAC"] - m["RAC w/o TSI"],
        }
    return results


def main():
    with Timer() as t:
        res = run()
    for k, v in res.items():
        emit(f"fig4/{k}", t.us / len(res),
             f"rac={v['RAC']:.4f} dTP={v['delta_tp']:+.4f} "
             f"dTSI={v['delta_tsi']:+.4f}")
    save_json("fig4.json", res)
    return res


if __name__ == "__main__":
    main()
