"""Slot-stall benchmark: blocking vs event-driven (async) admission.

Measures the time generation slots spend blocked on cache admission
(insert + RAC eviction scoring) in the serving engine:

  - **blocking**: every completed slot pays the full insert-then-evict
    cost inline (``slot_stall_s`` == the facade's ``admit_s``);
  - **async**: a completed slot only enqueues; the background worker
    drains off the slot loop and the engine settles the queue with one
    ``flush()`` per batch boundary while there are still waiting requests
    (``slot_stall_s`` == enqueue time, ``flush_s`` == boundary waits).

The cache is pre-filled to capacity so every admission triggers a victim
scan, which is the cost the async path moves off the request path.
Request outputs are identical in both modes (asserted here, tested in
``tests/test_serving.py``).

    PYTHONPATH=src python -m benchmarks.serving_async_bench
    PYTHONPATH=src python -m benchmarks.serving_async_bench --smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.core import SynthConfig, synthetic_trace
from repro.models import smoke_variant
from repro.serving import EngineConfig, ServingEngine
from repro.telemetry import CompositeTracker, InMemoryTracker

from .common import OUT_DIR, bench_tracker, emit, save_json


def _requests(n: int, vocab: int, seed: int = 7):
    trace = synthetic_trace(SynthConfig(trace_len=n, n_topics=24, seed=seed))
    rng = np.random.default_rng(seed)
    return [(r.cid, r.emb, list(rng.integers(2, vocab, size=4)))
            for r in trace.requests]


def run_once(async_admit: bool, n_requests: int, capacity: int,
             max_batch: int) -> dict:
    mcfg = smoke_variant(get_config("paper"))
    # per-mode in-memory tracker: admission-stall percentiles, the
    # hit-ratio-over-time series, and the request-path trace all come out
    # of this one sink (composed with the suite-wide --tracker sink, if
    # any).  Telemetry is observation-only — the output-parity assert in
    # main() holds with it attached.
    trk = InMemoryTracker()
    extra = bench_tracker()
    eng = ServingEngine(mcfg, EngineConfig(
        cache_capacity=capacity, max_new_tokens=8, max_batch=max_batch,
        max_seq=96, async_admit=async_admit,
        tracker=trk if extra is None else CompositeTracker([trk, extra])))
    # pre-fill to capacity: every admission during the run evicts
    rng = np.random.default_rng(3)
    warm = rng.standard_normal((capacity, eng.cfg.emb_dim)).astype(np.float32)
    warm /= np.linalg.norm(warm, axis=1, keepdims=True)
    for i in range(capacity):
        eng.cache.admit(10_000 + i, warm[i], payload=[0])
    eng.cache.flush()
    base_stall = eng.cache.metrics.admit_s       # exclude warmup from stall
    base_enq = (eng.cache.admitter.enqueue_s if eng.cache.admitter else 0.0)

    t0 = time.perf_counter()
    done = eng.run(_requests(n_requests, mcfg.vocab_size))
    wall = time.perf_counter() - t0
    s = eng.stats
    batches = max(1, s["batches"])
    if async_admit:
        slot_stall = eng.cache.admitter.enqueue_s - base_enq
        flush_s = eng.cache.admitter.flush_s
    else:
        slot_stall = eng.cache.metrics.admit_s - base_stall
        flush_s = 0.0
    row = {"mode": "async" if async_admit else "blocking",
           "requests": len(done), "batches": s["batches"], "wall_s": wall,
           "slot_stall_s": slot_stall, "flush_s": flush_s,
           "slot_stall_per_batch_us": 1e6 * slot_stall / batches,
           "hits": s["hits"], "evictions": s["evictions"]}
    # the SLO surface: admission-stall distribution + hit ratio over
    # logical time (windowed means of the per-lookup hit indicator)
    pct = trk.percentiles("cache.admit_stall_s") or {}
    row["admit_stall_p50_us"] = 1e6 * pct.get("p50", 0.0)
    row["admit_stall_p99_us"] = 1e6 * pct.get("p99", 0.0)
    row["hit_ratio_series"] = [
        {"t": p["t"], "hit_ratio": p["mean"], "lookups": p["count"]}
        for p in trk.series("cache.hit")]
    outputs = [(r.rid, r.cached, tuple(r.out_tokens)) for r in done]
    eng.close()
    if async_admit:
        # request-path spans (arrive→hit / queue→generate→complete) as a
        # chrome://tracing -loadable trace for the async run
        import os
        os.makedirs(OUT_DIR, exist_ok=True)
        trk.export_chrome(os.path.join(OUT_DIR, "serving_async_trace.json"))
    return row, outputs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--capacity", type=int, default=None)
    args = ap.parse_args(argv)
    n = args.requests or (48 if args.smoke else 192)
    cap = args.capacity or (512 if args.smoke else 2048)
    rows = []
    out_by_mode = {}
    for async_admit in (False, True):
        row, outputs = run_once(async_admit, n, cap, max_batch=16)
        out_by_mode[row["mode"]] = outputs
        rows.append(row)
        emit(f"serving_admit/{row['mode']}",
             row["slot_stall_per_batch_us"],
             f"slot_stall={row['slot_stall_s'] * 1e3:.2f}ms,"
             f"flush={row['flush_s'] * 1e3:.2f}ms,hits={row['hits']},"
             f"stall_p50={row['admit_stall_p50_us']:.1f}us,"
             f"stall_p99={row['admit_stall_p99_us']:.1f}us")
    assert out_by_mode["blocking"] == out_by_mode["async"], \
        "async admission changed request outputs"
    stall = {r["mode"]: r["slot_stall_s"] for r in rows}
    speedup = stall["blocking"] / max(stall["async"], 1e-9)
    emit("serving_admit/speedup", 0.0, f"slot_stall_ratio={speedup:.1f}x")
    save_json("serving_async_bench.json",
              {"rows": rows, "slot_stall_speedup": speedup})
    return rows


if __name__ == "__main__":
    main()
